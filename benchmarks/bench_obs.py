"""Observability overhead benchmark — instrumented vs uninstrumented
OWLQN+ train-step wall.

The obs layer (``repro.obs``) promises a near-free disabled fast path
and cheap enabled instrumentation: a span is two ``perf_counter_ns``
calls, a ledger record one dict + one JSONL line. This bench drives the
SAME warmed, jitted sparse train step through two identical host loops —
one against the null tracer/ledger (obs off: exactly what an
un-instrumented run pays), one against an enabled :class:`~repro.obs.Tracer`
plus a file-backed :class:`~repro.obs.RunLedger` — and reports the wall
ratio. Both loops mirror ``OWLQNPlus.run``'s per-iteration work
(device_get of the step stats included), so the ratio isolates the
instrumentation itself.

The trajectory must be BIT-IDENTICAL between modes (observation never
feeds back into the math) — asserted before timing counts.

Enforcement: with REPRO_BENCH_ENFORCE=1 (and not --smoke) the
instrumented loop must stay within :data:`MAX_OVERHEAD` (2%) of the
uninstrumented wall — the ISSUE's "overhead measured and negligible"
gate. Reps interleave base/instrumented and keep each mode's best wall
so slow-drift on shared runners cancels.

CSV rows: obs/{base,instrumented}/<tag>,us_per_iter and an
obs/overhead/<tag> ratio row; ``benchmarks/run.py --json`` writes the
same numbers into BENCH_obs.json.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import obs

# (sessions, d, m, iters) — the step wall must dwarf per-iter
# instrumentation (~tens of us) for a 2% gate to be meaningful, so the
# enforced config is a mid-size sparse problem (~tens of ms per step)
CONFIGS = [(1024, 100_000, 8, 8)]
SMOKE_CONFIGS = [(64, 5_000, 2, 4)]
MAX_OVERHEAD = 1.02
REPS = 3


def _make_step(sessions: int, d: int, m: int):
    from repro.core.objective import smooth_loss_and_grad
    from repro.data.sparse import generate_sparse
    from repro.optim import OWLQNPlus

    train = generate_sparse(
        num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
        sessions=sessions, seed=3)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(5).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                    lam=0.1, beta=0.1)
    state0 = opt.init(theta0)
    step_fn = jax.jit(opt.step)
    state, stats = step_fn(state0)  # one compile + warm step
    jax.block_until_ready(state.theta)
    return step_fn, state0


def _drive(step_fn, state0, iters: int, tracer, ledger):
    """One timed loop mirroring ``OWLQNPlus.run``'s per-iteration
    instrumentation (span + stats device_get + guarded ledger emit)."""
    state = state0
    fs = []
    t_start = time.perf_counter()
    for k in range(iters):
        t0 = time.perf_counter()
        with tracer.step_span("train/iter", k):
            state, stats = step_fn(state)
            st = jax.device_get(stats)
        if ledger.enabled:
            ledger.emit(
                "train_iter", step=k, f=float(st.f), f_new=float(st.f_new),
                alpha=float(st.alpha), ls_iters=int(st.ls_iters),
                grad_norm=float(st.grad_norm), nnz=int(st.nnz),
                wall_s=time.perf_counter() - t0)
        fs.append(float(st.f_new))
    wall = time.perf_counter() - t_start
    return wall, fs


def run(smoke: bool | None = None, collect: dict | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    rows = []
    results: dict = {}
    if collect is not None:
        collect["backend"] = jax.default_backend()
        collect["smoke"] = smoke
        collect["max_overhead_ratio"] = MAX_OVERHEAD
        collect["configs"] = results

    ratios = []
    for sessions, d, m, iters in configs:
        tag = f"G{sessions}_d{d}_m{m}_i{iters}"
        step_fn, state0 = _make_step(sessions, d, m)
        base_wall = instr_wall = float("inf")
        base_fs = instr_fs = None
        with tempfile.TemporaryDirectory() as tmp:
            for rep in range(REPS):  # interleave so drift hits both modes
                wall, fs = _drive(step_fn, state0, iters,
                                  obs.NULL_TRACER, obs.NULL_LEDGER)
                if wall < base_wall:
                    base_wall, base_fs = wall, fs
                tracer = obs.Tracer(enabled=True)
                ledger = obs.RunLedger(f"{tmp}/ledger_{rep}.jsonl")
                wall, fs = _drive(step_fn, state0, iters, tracer, ledger)
                ledger.close()
                if wall < instr_wall:
                    instr_wall, instr_fs = wall, fs
        assert base_fs == instr_fs, \
            f"obs changed the trajectory: {base_fs} != {instr_fs}"
        ratio = instr_wall / base_wall
        ratios.append(ratio)
        rows.append((f"obs/base/{tag}", base_wall * 1e6 / iters,
                     f"{iters / base_wall:.2f}steps_per_sec"))
        rows.append((f"obs/instrumented/{tag}", instr_wall * 1e6 / iters,
                     f"{iters / instr_wall:.2f}steps_per_sec"))
        rows.append((f"obs/overhead/{tag}", 0.0,
                     f"{ratio:.4f}x_instr_vs_base"))
        results[tag] = {
            "sessions": sessions, "d": d, "m": m, "iters": iters,
            "base_us_per_iter": base_wall * 1e6 / iters,
            "instrumented_us_per_iter": instr_wall * 1e6 / iters,
            "overhead_ratio": ratio,
            "parity": "ok",
        }

    emit(rows)
    if enforce and not smoke:
        worst = max(ratios)
        if worst > MAX_OVERHEAD:
            raise AssertionError(
                f"obs instrumentation overhead {worst:.4f}x exceeds the "
                f"{MAX_OVERHEAD}x train-step gate; per-config: "
                f"{[round(r, 4) for r in ratios]}")
    return results
