"""Observability overhead benchmark — instrumented vs uninstrumented
OWLQN+ train-step wall.

The obs layer (``repro.obs``) promises a near-free disabled fast path
and cheap enabled instrumentation: a span is two ``perf_counter_ns``
calls, a ledger record one dict + one JSONL line. This bench drives the
SAME warmed, jitted sparse train step through two identical host loops —
one against the null tracer/ledger (obs off: exactly what an
un-instrumented run pays), one against an enabled :class:`~repro.obs.Tracer`
plus a file-backed :class:`~repro.obs.RunLedger` — and reports the wall
ratio. Both loops mirror ``OWLQNPlus.run``'s per-iteration work
(device_get of the step stats included), so the ratio isolates the
instrumentation itself.

The trajectory must be BIT-IDENTICAL between modes (observation never
feeds back into the math) — asserted before timing counts.

Enforcement: with REPRO_BENCH_ENFORCE=1 (and not --smoke) the
instrumented loop must stay within :data:`MAX_OVERHEAD` (2%) of the
uninstrumented wall — the ISSUE's "overhead measured and negligible"
gate. Reps interleave base/instrumented and keep each mode's best wall
so slow-drift on shared runners cancels.

The second section gates the HEALTH MONITOR the same way: the same
warmed engine serves the same request replay under a null monitor and
under a ``--monitor``-equivalent setup (attached
:class:`~repro.obs.HealthMonitor` with armed drift trackers, exactly
what ``repro.launch.serve --monitor --drift-ref`` installs) — and the
monitored dispatch loop must stay within the same 2% of the
unmonitored wall, with bitwise score parity asserted first. Both sides
run with an in-memory ledger (``--monitor`` implies one), so the delta
isolates the monitor's own per-dispatch work: the ingest windows, the
subsampled drift feeds, and the amortised rule evaluations. The
estimator is paired (order-swapped back-to-back replays, median of
per-pair wall ratios) and the gate takes the best of
:data:`SERVE_TRIALS` independent trials, because shared-runner noise
only ever inflates an ms-scale replay wall.

CSV rows: obs/{base,instrumented}/<tag>,us_per_iter and an
obs/overhead/<tag> ratio row (plus obs/serve_{base,monitored,overhead}
for the monitor section); ``benchmarks/run.py --json`` writes the same
numbers into BENCH_obs.json.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import obs

# (sessions, d, m, iters) — the step wall must dwarf per-iter
# instrumentation (~tens of us) for a 2% gate to be meaningful, so the
# enforced config is a mid-size sparse problem (~tens of ms per step)
CONFIGS = [(1024, 100_000, 8, 8)]
SMOKE_CONFIGS = [(64, 5_000, 2, 4)]
# (d, m, requests, pairs) for the monitored serve-dispatch section —
# production-shaped traffic (hundreds of candidates per request, G=16
# dispatches) so the dispatch wall dwarfs the monitor's capped
# per-dispatch work and the 2% gate measures overhead, not noise
SERVE_CONFIGS = [(200_000, 16, 48, 40)]
SERVE_SMOKE_CONFIGS = [(20_000, 4, 16, 4)]
SERVE_TRIALS = 3
MAX_OVERHEAD = 1.02
REPS = 3


def _make_step(sessions: int, d: int, m: int):
    from repro.core.objective import smooth_loss_and_grad
    from repro.data.sparse import generate_sparse
    from repro.optim import OWLQNPlus

    train = generate_sparse(
        num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
        sessions=sessions, seed=3)
    theta0 = jnp.asarray(
        0.01 * np.random.default_rng(5).normal(size=(d, 2 * m)), jnp.float32)
    opt = OWLQNPlus(lambda t: smooth_loss_and_grad(t, train),
                    lam=0.1, beta=0.1)
    state0 = opt.init(theta0)
    step_fn = jax.jit(opt.step)
    state, stats = step_fn(state0)  # one compile + warm step
    jax.block_until_ready(state.theta)
    return step_fn, state0


def _drive(step_fn, state0, iters: int, tracer, ledger):
    """One timed loop mirroring ``OWLQNPlus.run``'s per-iteration
    instrumentation (span + stats device_get + guarded ledger emit)."""
    state = state0
    fs = []
    t_start = time.perf_counter()
    for k in range(iters):
        t0 = time.perf_counter()
        with tracer.step_span("train/iter", k):
            state, stats = step_fn(state)
            st = jax.device_get(stats)
        if ledger.enabled:
            ledger.emit(
                "train_iter", step=k, f=float(st.f), f_new=float(st.f_new),
                alpha=float(st.alpha), ls_iters=int(st.ls_iters),
                grad_norm=float(st.grad_norm), nnz=int(st.nnz),
                wall_s=time.perf_counter() - t0)
        fs.append(float(st.f_new))
    wall = time.perf_counter() - t_start
    return wall, fs


def _make_serve(d: int, m: int, n_requests: int):
    """Warmed engine + fixed request replay + a drift reference captured
    from the replay's own score/id distribution (the no-drift case: the
    monitor must stay quiet while its trackers do full work)."""
    from repro.serve import ScoringEngine, synthetic_requests

    rng = np.random.default_rng(7)
    theta = jnp.asarray(
        (0.3 * rng.normal(size=(d, 2 * m))).astype(np.float32))
    reqs = synthetic_requests(n_requests, num_features=d, seed=11,
                              k_user=(48, 48), k_ad=(24, 24),
                              n_ads=(512, 512))
    engine = ScoringEngine(theta)
    scores = np.concatenate(engine.score_batch(reqs))  # compiles + warms
    labels = (rng.random(scores.shape[0]) < scores).astype(np.float64)
    ids = np.concatenate([r.user_ids.ravel() for r in reqs]
                         + [r.ad_ids.ravel() for r in reqs])
    ref = obs.capture_reference(scores, labels, ids, num_features=d)
    return engine, reqs, ref


def _drive_serve(engine, reqs, ledger, monitor):
    """One timed replay of the request set through ``score_batch``
    under the given process ledger/monitor defaults (restored after)."""
    prev_led = obs.set_ledger(ledger)
    prev_mon = obs.set_monitor(monitor)
    try:
        t_start = time.perf_counter()
        outs = engine.score_batch(reqs)
        wall = time.perf_counter() - t_start
    finally:
        obs.set_monitor(prev_mon)
        obs.set_ledger(prev_led)
    return wall, outs


def run(smoke: bool | None = None, collect: dict | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    rows = []
    results: dict = {}
    if collect is not None:
        collect["backend"] = jax.default_backend()
        collect["smoke"] = smoke
        collect["max_overhead_ratio"] = MAX_OVERHEAD
        collect["configs"] = results

    ratios = []
    for sessions, d, m, iters in configs:
        tag = f"G{sessions}_d{d}_m{m}_i{iters}"
        step_fn, state0 = _make_step(sessions, d, m)
        base_wall = instr_wall = float("inf")
        base_fs = instr_fs = None
        with tempfile.TemporaryDirectory() as tmp:
            for rep in range(REPS):  # interleave so drift hits both modes
                wall, fs = _drive(step_fn, state0, iters,
                                  obs.NULL_TRACER, obs.NULL_LEDGER)
                if wall < base_wall:
                    base_wall, base_fs = wall, fs
                tracer = obs.Tracer(enabled=True)
                ledger = obs.RunLedger(f"{tmp}/ledger_{rep}.jsonl")
                wall, fs = _drive(step_fn, state0, iters, tracer, ledger)
                ledger.close()
                if wall < instr_wall:
                    instr_wall, instr_fs = wall, fs
        assert base_fs == instr_fs, \
            f"obs changed the trajectory: {base_fs} != {instr_fs}"
        ratio = instr_wall / base_wall
        ratios.append(ratio)
        rows.append((f"obs/base/{tag}", base_wall * 1e6 / iters,
                     f"{iters / base_wall:.2f}steps_per_sec"))
        rows.append((f"obs/instrumented/{tag}", instr_wall * 1e6 / iters,
                     f"{iters / instr_wall:.2f}steps_per_sec"))
        rows.append((f"obs/overhead/{tag}", 0.0,
                     f"{ratio:.4f}x_instr_vs_base"))
        results[tag] = {
            "sessions": sessions, "d": d, "m": m, "iters": iters,
            "base_us_per_iter": base_wall * 1e6 / iters,
            "instrumented_us_per_iter": instr_wall * 1e6 / iters,
            "overhead_ratio": ratio,
            "parity": "ok",
        }

    serve_ratios = []
    for d, m, n_requests, pairs in (SERVE_SMOKE_CONFIGS if smoke
                                    else SERVE_CONFIGS):
        tag = f"d{d}_m{m}_r{n_requests}_p{pairs}"
        engine, reqs, ref = _make_serve(d, m, n_requests)
        # the monitor always rides on a ledger (`--monitor` implies an
        # in-memory one), so the base side carries the SAME ledger and
        # the delta isolates what the monitor itself adds per dispatch —
        # section 1 already gates the ledger. Estimation is PAIRED:
        # each pair runs both modes back-to-back (order swapped pair by
        # pair so position bias cancels) and a trial's reading is the
        # median of the per-pair wall ratios — adjacent replays share
        # the runner's slow drift, which a best-of-longer-drives
        # comparison cannot cancel. Shared-runner noise only ever
        # INFLATES walls, so the gate takes the best of SERVE_TRIALS
        # independent trials — the same reasoning as the train
        # section's best-of-reps.
        base_ledger = obs.RunLedger(None)
        trial_ratios, trial_walls = [], []
        for _trial in range(SERVE_TRIALS):
            # fresh monitor per trial, exactly what `repro.launch.serve
            # --monitor --drift-ref` installs: attached, drift trackers
            # armed and feeding off every dispatch
            ledger = obs.RunLedger(None)
            monitor = obs.HealthMonitor().attach(ledger)
            monitor.arm_drift(ref)
            base_walls, mon_walls = [], []
            base_out = mon_out = None
            for rep in range(pairs):
                if rep % 2 == 0:
                    wall, base_out = _drive_serve(
                        engine, reqs, base_ledger, obs.NULL_MONITOR)
                    base_walls.append(wall)
                    wall, mon_out = _drive_serve(engine, reqs,
                                                 ledger, monitor)
                    mon_walls.append(wall)
                else:
                    wall, mon_out = _drive_serve(engine, reqs,
                                                 ledger, monitor)
                    mon_walls.append(wall)
                    wall, base_out = _drive_serve(
                        engine, reqs, base_ledger, obs.NULL_MONITOR)
                    base_walls.append(wall)
            monitor.detach()
            assert all(np.array_equal(a, b)
                       for a, b in zip(base_out, mon_out)), \
                f"monitor changed the scores ({tag})"
            trial_ratios.append(float(np.median(
                np.asarray(mon_walls) / np.asarray(base_walls))))
            trial_walls.append((float(np.median(base_walls)) * 1e6,
                                float(np.median(mon_walls)) * 1e6))
        best = int(np.argmin(trial_ratios))
        ratio = trial_ratios[best]
        base_us, mon_us = trial_walls[best]
        serve_ratios.append(ratio)
        rows.append((f"obs/serve_base/{tag}", base_us,
                     f"{1e6 / base_us:.2f}replays_per_sec"))
        rows.append((f"obs/serve_monitored/{tag}", mon_us,
                     f"{1e6 / mon_us:.2f}replays_per_sec"))
        rows.append((f"obs/serve_overhead/{tag}", 0.0,
                     f"{ratio:.4f}x_monitored_vs_base"))
        results[f"serve_{tag}"] = {
            "d": d, "m": m, "requests": n_requests, "pairs": pairs,
            "trials": SERVE_TRIALS,
            # medians of the winning trial; the ratio is that trial's
            # paired estimator (median of per-pair ratios), so it need
            # not equal monitored/base exactly
            "base_us_per_iter": base_us,
            "monitored_us_per_iter": mon_us,
            "overhead_ratio": ratio,
            "parity": "ok",
        }

    emit(rows)
    if enforce and not smoke:
        worst = max(ratios)
        if worst > MAX_OVERHEAD:
            raise AssertionError(
                f"obs instrumentation overhead {worst:.4f}x exceeds the "
                f"{MAX_OVERHEAD}x train-step gate; per-config: "
                f"{[round(r, 4) for r in ratios]}")
        worst_serve = max(serve_ratios)
        if worst_serve > MAX_OVERHEAD:
            raise AssertionError(
                f"health-monitor overhead {worst_serve:.4f}x exceeds the "
                f"{MAX_OVERHEAD}x serve-dispatch gate; per-config: "
                f"{[round(r, 4) for r in serve_ratios]}")
    return results
