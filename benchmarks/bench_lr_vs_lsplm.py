"""Fig. 5 — LS-PLM vs LR across 7 sequential datasets ('days').

Paper claim: LS-PLM consistently beats LR on every dataset (avg +1.4% AUC
absolute on production data; larger here because the synthetic truth is
exactly piecewise-linear).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, eval_auc, fit_lsplm, load_split
from repro.core import predict_proba
from repro.core.lsplm import params_from_theta
from repro.data import to_dense_batch
from repro.eval import normalized_entropy, calibration_ratio

DAYS = 7


def run():
    rows = []
    gaps = []
    for day in range(DAYS):
        train_cf, test_cf = load_split(day=day)
        theta_lr, _ = fit_lsplm(train_cf, m=1, lam=0.0, beta=1.0, iters=30)
        theta_plm, _ = fit_lsplm(train_cf, m=12, lam=1.0, beta=1.0, iters=70)
        a_lr = eval_auc(theta_lr, test_cf)
        a_plm = eval_auc(theta_plm, test_cf)
        test = to_dense_batch(test_cf)
        p_plm = np.asarray(predict_proba(params_from_theta(theta_plm),
                                         jnp.asarray(test.x)))
        ne = normalized_entropy(test.y, p_plm)
        cal = calibration_ratio(test.y, p_plm)
        gaps.append(a_plm - a_lr)
        rows.append((
            f"fig5_day{day + 1}", "0",
            f"auc_lr={a_lr:.4f};auc_lsplm={a_plm:.4f};gap={a_plm - a_lr:+.4f};"
            f"ne_lsplm={ne:.4f};calibration={cal:.3f}",
        ))
    rows.append(("fig5_mean_gap", "0",
                 f"mean_auc_improvement={float(np.mean(gaps)):+.4f};"
                 f"consistent={all(g > 0 for g in gaps)}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
