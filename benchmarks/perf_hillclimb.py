import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (dry-run device stand-ins).

"""§Perf hillclimb driver: lowers baseline + optimised variants of the
three chosen (arch x shape) pairs and reports the roofline-term deltas.

  PYTHONPATH=src:. python -m benchmarks.perf_hillclimb --pair dbrx_decode
  PYTHONPATH=src:. python -m benchmarks.perf_hillclimb --pair qwen_train
  PYTHONPATH=src:. python -m benchmarks.perf_hillclimb --pair lsplm

Each variant is recorded separately (paper-faithful baseline vs
beyond-paper optimisation) in benchmarks/perf_results.json.
"""
import argparse
import dataclasses
import json
import time


from repro.configs import get_config
from repro.launch.dryrun import analyse, lower_combo
from repro.launch.mesh import make_production_mesh

OUT = os.path.join(os.path.dirname(__file__), "perf_results.json")


def measure(name, cfg, shape, mesh, **lower_kwargs):
    t0 = time.time()
    _, compiled, meta = lower_combo(cfg, shape, mesh, **lower_kwargs)
    rec = analyse(cfg.name, shape, "single", compiled, cfg, meta, mesh,
                  probes=True, lower_kwargs=lower_kwargs)
    rec["variant"] = name
    rec["compile_seconds"] = round(time.time() - t0, 1)
    r = rec["roofline"]
    mem = rec["memory"]["total_bytes_per_chip"] / 2**30
    print(f"[{name}] mem/chip={mem:7.2f}GiB "
          f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
          f"t_coll={r['t_collective_s']:.3e} bound={r['bottleneck']}",
          flush=True)
    return rec


def save(recs):
    old = []
    if os.path.exists(OUT):
        with open(OUT) as f:
            old = json.load(f)
    with open(OUT, "w") as f:
        json.dump(old + recs, f, indent=1)


def pair_dbrx_decode():
    """Most collective-bound: dbrx-132b x decode_32k.

    Iter 1 hypothesis (napkin): baseline all-gathers the FSDP-sharded
    expert weights at every layer (~40 x 396 MB/chip -> t_coll ~2.6 s
    measured). token_gather moves the 128 token activations instead ->
    predicted t_coll collapse.
    MEASURED: 2.58 -> 1.95 s only — PARTIALLY REFUTED. The compiled HLO
    warnings show the true dominator: the KV cache (hd-sharded storage)
    is RESHARDED to heads-over-model at every layer's attention
    (replicate-then-partition of a 2.7 GB cache slice).
    Iter 2 hypothesis: attention sharded on head_dim matches the cache
    layout — removes the cache resharding entirely at the price of a
    50 MB fp32 scores psum per layer (~2 GB total ≈ 0.04 s)."""
    mesh = make_production_mesh()
    cfg = get_config("dbrx-132b")
    recs = [
        measure("dbrx_decode/baseline_weight_gather", cfg, "decode_32k", mesh),
        measure("dbrx_decode/opt1_token_gather", cfg, "decode_32k", mesh,
                moe_serving_mode="token_gather"),
    ]
    save(recs)


def pair_dbrx_decode_round2():
    mesh = make_production_mesh()
    cfg = get_config("dbrx-132b")
    recs = [
        measure("dbrx_decode/opt2_tg+hd_shard",
                dataclasses.replace(cfg, attn_shard="head_dim"),
                "decode_32k", mesh, moe_serving_mode="token_gather"),
    ]
    save(recs)


def pair_qwen_train():
    """Worst roofline fraction: qwen1.5-32b x train_4k (124.8 GiB/chip).

    Iteration 1 hypothesis: the dominant saved tensor is the per-layer
    scan carry h (B,S,d) — 64 x 16x4096x5120 x 2B = 42.9 GiB/chip — plus
    SPMD resharding copies from the H=40-vs-16-shards conflict.
    (a) sequence parallelism: shard the inter-block h on S over `model`
        -> saved carries /16 (predict -40 GiB).
    (b) attention sharded on head_dim (128 % 16 == 0) instead of padded
        heads -> removes the involuntary-full-remat copies."""
    mesh = make_production_mesh()
    cfg = get_config("qwen1.5-32b")
    recs = [measure("qwen_train/baseline", cfg, "train_4k", mesh)]
    recs.append(measure(
        "qwen_train/opt1_seq_parallel",
        dataclasses.replace(cfg, seq_parallel=True), "train_4k", mesh))
    recs.append(measure(
        "qwen_train/opt2_seqpar+hd_shard",
        dataclasses.replace(cfg, seq_parallel=True, attn_shard="head_dim"),
        "train_4k", mesh))
    save(recs)


def pair_lsplm():
    """The paper's own job — see repro.launch.dryrun_lsplm variants."""
    from repro.launch import dryrun_lsplm as dl
    recs = []
    for variant in ("baseline", "bf16_features", "bf16+m5_history",
                    "cf8_sessions"):
        rec = dl.run("single", variant=variant)
        rec["variant"] = f"lsplm/{variant}"
        r = rec["roofline"]
        recs.append(rec)
    save(recs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=["dbrx_decode", "dbrx_decode2",
                                       "qwen_train", "lsplm"],
                    required=True)
    args = ap.parse_args()
    {"dbrx_decode": pair_dbrx_decode,
     "dbrx_decode2": pair_dbrx_decode_round2,
     "qwen_train": pair_qwen_train,
     "lsplm": pair_lsplm}[args.pair]()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
