"""Serving benchmark — pruned-vs-full artifacts and session-shared
vs naive per-ad bundle scoring (the `repro.serve` subsystem).

Three families of rows, all on production-like shapes (K active ids out
of d columns, N-candidate page-view bundles):

  * serve/flat_{full,pruned}/<tag> — flat padded-COO scoring of the full
    Theta vs the pruned artifact (same requests, scores BIT-IDENTICAL —
    asserted before timing counts; the artifact's win is the deployed
    size, recorded in the derived column and the JSON);
  * serve/bundles_{naive,shared}/<tag> — per-page-view bundle scoring
    with the user contraction repeated for every candidate (naive) vs
    computed once per bundle and broadcast (the serving side of Eq. 13).
    With REPRO_BENCH_ENFORCE=1 (and not --smoke) the shared path must
    reach SERVE_TARGET_SPEEDUP (1.5x) bundle throughput;
  * serve/engine/<tag> — the ScoringEngine replaying ragged traffic:
    reports per-request latency / candidate throughput and ASSERTS the
    steady state (post-warmup) triggered zero recompiles.

Quality gates ride along: pruned and full scores must agree exactly, so
their AUC and calibration against the planted labels agree exactly too
(recorded in BENCH_serve.json via ``benchmarks/run.py --json``).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

SERVE_TARGET_SPEEDUP = 1.5  # shared-vs-naive bundle throughput (enforced)

# (d, m, nnz_frac, sessions, ads_per_session, Ku, Ka, flat_requests)
CONFIGS = [
    (500_000, 12, 0.05, 64, 30, 24, 12, 4096),
    (200_000, 12, 0.02, 128, 16, 24, 8, 4096),
]
SMOKE_CONFIGS = [(5_000, 4, 0.10, 8, 4, 8, 5, 64)]


def _model(d, m, nnz, seed=0):
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.05
    theta[rng.random(d) >= nnz] = 0.0  # exact-zero rows (the L2,1 pattern)
    return jnp.asarray(theta)


def run(smoke: bool | None = None, collect: dict | None = None):
    from repro.data.sparse import generate_sparse
    from repro.eval import auc, calibration_ratio
    from repro.serve import (
        ScoreBundle,
        ScoringEngine,
        as_model,
        compress,
        score_bundles,
        score_bundles_naive,
        score_sparse,
        synthetic_requests,
    )

    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    rows = []
    results: dict = {}
    if collect is not None:  # bind BEFORE the sweep: a failing run still
        collect["backend"] = jax.default_backend()  # leaves partial data
        collect["smoke"] = smoke
        collect["target_speedup"] = SERVE_TARGET_SPEEDUP
        collect["configs"] = results

    speedups = []
    for (d, m, nnz, G, A, ku, ka, n_flat) in configs:
        tag = f"d{d}_m{m}_G{G}x{A}"
        theta = _model(d, m, nnz)
        full = as_model(theta)
        art = compress(theta)

        # ---- flat path: pruned vs full, bit-identical scores
        rng = np.random.default_rng(1)
        ids = jnp.asarray(rng.integers(0, d, (n_flat, ku)), jnp.int32)
        vals = jnp.asarray(
            rng.normal(size=(n_flat, ku)).astype(np.float32) / np.sqrt(ku))
        flat_full = jax.jit(lambda i, v: score_sparse(full, i, v))
        flat_pruned = jax.jit(lambda i, v: score_sparse(art, i, v))
        np.testing.assert_array_equal(np.asarray(flat_full(ids, vals)),
                                      np.asarray(flat_pruned(ids, vals)))
        t_ff = time_fn(flat_full, ids, vals)
        t_fp = time_fn(flat_pruned, ids, vals)
        size_ratio = (art.theta.size + art.remap.size + art.alive_ids.size) \
            / theta.size
        rows.append((f"serve/flat_full/{tag}", t_ff,
                     f"{n_flat / (t_ff / 1e6):.0f}ads_per_sec"))
        rows.append((f"serve/flat_pruned/{tag}", t_fp,
                     f"{n_flat / (t_fp / 1e6):.0f}ads_per_sec;"
                     f"alive={art.compression:.3f};"
                     f"deployed_size_ratio={size_ratio:.3f};parity=bitwise"))

        # ---- bundles: session-shared vs naive per-ad (pruned model, the
        # production deployment) + AUC/calibration quality gates
        batch = generate_sparse(
            num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
            sessions=G, ads_per_session=A, active_user=ku, active_ad=ka,
            seed=2, with_plans=False)
        bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                             batch.ad_ids, batch.ad_vals, batch.session_id)
        shared = jax.jit(lambda b: score_bundles(art, b))
        naive = jax.jit(lambda b: score_bundles_naive(art, b))
        p_shared = np.asarray(shared(bundle))
        p_naive = np.asarray(naive(bundle))
        np.testing.assert_allclose(p_shared, p_naive, rtol=1e-5, atol=1e-6)
        # pruned-vs-full parity holds BITWISE under the same compilation
        # regime (both jitted here; eager-vs-jit is the usual 1-ulp apart)
        p_full_shared = np.asarray(jax.jit(
            lambda b: score_bundles(full, b))(bundle))
        np.testing.assert_array_equal(p_shared, p_full_shared)
        y = np.asarray(batch.y)
        quality = {
            "auc_pruned": auc(y, p_shared),
            "auc_full": auc(y, p_full_shared),
            "calibration_pruned": calibration_ratio(y, p_shared),
            "calibration_full": calibration_ratio(y, p_full_shared),
        }
        assert quality["auc_pruned"] == quality["auc_full"]
        t_sh = time_fn(shared, bundle)
        t_nv = time_fn(naive, bundle)
        speedup = t_nv / t_sh
        speedups.append(speedup)
        B = bundle.ad_ids.shape[0]
        rows.append((f"serve/bundles_naive/{tag}", t_nv,
                     f"{B / (t_nv / 1e6):.0f}ads_per_sec"))
        rows.append((f"serve/bundles_shared/{tag}", t_sh,
                     f"{B / (t_sh / 1e6):.0f}ads_per_sec;"
                     f"{speedup:.2f}x_vs_naive"))

        # ---- engine on ragged traffic: steady state must not recompile
        engine = ScoringEngine(art)
        requests = synthetic_requests(
            16 if smoke else 128, num_features=d,
            k_user=(max(2, ku // 2), ku), k_ad=(max(2, ka // 2), ka),
            n_ads=(max(2, A // 2), A), seed=3)
        engine.warm({engine.envelope(r) for r in requests})  # deploy-time
        warm_compiles = engine.stats.compiles
        engine.score_many(requests)
        s = engine.stats
        assert s.compiles == warm_compiles, \
            f"engine recompiled in steady state ({s.compiles} != {warm_compiles})"
        rows.append((f"serve/engine/{tag}", s.latency_us,
                     f"{s.candidates_per_sec:.0f}ads_per_sec;"
                     f"buckets={len(s.bucket_hits)};compiles={s.compiles};"
                     "steady_state_recompiles=0"))

        results[tag] = {
            "d": d, "m": m, "nnz_frac": nnz, "sessions": G,
            "ads_per_session": A, "k_user": ku, "k_ad": ka,
            "alive_rows": art.num_alive,
            "deployed_size_ratio": float(size_ratio),
            "flat_full_us": t_ff, "flat_pruned_us": t_fp,
            "bundles_naive_us": t_nv, "bundles_shared_us": t_sh,
            "shared_speedup": speedup,
            "engine": s.as_dict(),
            "quality": quality,
            "parity": "bitwise",
        }

    geomean = float(np.exp(np.mean(np.log(speedups))))
    rows.append(("serve/shared_speedup/geomean", 0.0, f"{geomean:.2f}x_vs_naive"))
    if collect is not None:
        collect["shared_speedup_geomean"] = geomean
    emit(rows)  # before the gate: a failed target must not eat the rows
    if enforce and not smoke and geomean < SERVE_TARGET_SPEEDUP:
        raise AssertionError(
            f"session-shared bundle scoring only {geomean:.2f}x vs the naive "
            f"per-ad path (target {SERVE_TARGET_SPEEDUP}x); per-config: "
            f"{[round(s, 2) for s in speedups]}")
    return results
