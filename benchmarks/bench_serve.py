"""Serving benchmark — pruned-vs-full artifacts and session-shared
vs naive per-ad bundle scoring (the `repro.serve` subsystem).

Three families of rows, all on production-like shapes (K active ids out
of d columns, N-candidate page-view bundles):

  * serve/flat_{full,pruned}/<tag> — flat padded-COO scoring of the full
    Theta vs the pruned artifact (same requests, scores BIT-IDENTICAL —
    asserted before timing counts; the artifact's win is the deployed
    size, recorded in the derived column and the JSON);
  * serve/bundles_{naive,shared}/<tag> — per-page-view bundle scoring
    with the user contraction repeated for every candidate (naive) vs
    computed once per bundle and broadcast (the serving side of Eq. 13).
    With REPRO_BENCH_ENFORCE=1 (and not --smoke) the shared path must
    reach SERVE_TARGET_SPEEDUP (1.5x) bundle throughput;
  * serve/engine_{single,batched}/<tag> — the ScoringEngine replaying
    ragged traffic one request per dispatch vs stacked same-envelope
    G>1 dispatches (same scores — parity-asserted bitwise — the win is
    amortised dispatch); batched must reach BATCH_TARGET_SPEEDUP (1.3x)
    candidates/sec at smoke shapes, and BOTH replays must run the steady
    state (post-warmup) with ZERO recompiles (asserted);
  * serve/int8/<tag> — the int8-quantised artifact after a save/load
    round trip: scores must stay within INT8_MAX_DP (1e-2) of fp32
    (asserted), deployed payload ~4x smaller rows;
  * serve/int8_{native,dequantized}/<tag> — the engine serving the
    QuantizedArtifact int8-NATIVE (codes DMA'd as int8, per-row scale
    fused into the gather epilogue) vs serving dequantize(q) (fp32 rows
    rebuilt up front). Scores agree to <= 1e-6 (asserted before timing);
    the native row-gather moves rows_dma_ratio = (2m+4)/(8m) of the
    bytes. With REPRO_BENCH_ENFORCE=1 (and not --smoke) the native path
    must reach INT8_TARGET_SPEEDUP (1.3x) candidates/sec on KERNEL
    backends (tpu), where the win is DMA bytes; on cpu the jnp fallback
    does the same flops either way, so the row is informational;
  * serve/coalesce_{off,on}/<tag> — the same Poisson arrival tape
    through the micro-batching queue with cross-envelope coalescing off
    vs on: per-ticket scores BITWISE identical (asserted), the win is
    fewer device rounds at deadline-dominated rates;
  * serve/load_qps*/<tag> — open-loop Poisson traffic through the
    micro-batching queue (deadline-aware flushing + admission control):
    p50/p99 request latency and candidates/sec at each offered QPS —
    the "millions of users" claim, measured.

Quality gates ride along: pruned and full scores must agree exactly, so
their AUC and calibration against the planted labels agree exactly too
(recorded in BENCH_serve.json via ``benchmarks/run.py --json``).
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

SERVE_TARGET_SPEEDUP = 1.5  # shared-vs-naive bundle throughput (enforced)
BATCH_TARGET_SPEEDUP = 1.3  # batched-vs-single engine dispatch (smoke gate)
INT8_MAX_DP = 1e-2  # max |p_int8 - p_fp32| after a save/load round trip
INT8_TARGET_SPEEDUP = 1.3  # int8-native vs dequantized engine (kernel
INT8_NATIVE_TOL = 1e-6  # .. backends only; the win is row-DMA bytes)

# (d, m, nnz_frac, sessions, ads_per_session, Ku, Ka, flat_requests)
CONFIGS = [
    (500_000, 12, 0.05, 64, 30, 24, 12, 4096),
    (200_000, 12, 0.02, 128, 16, 24, 8, 4096),
]
SMOKE_CONFIGS = [(5_000, 4, 0.10, 8, 4, 8, 5, 64)]


def _model(d, m, nnz, seed=0):
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.05
    theta[rng.random(d) >= nnz] = 0.0  # exact-zero rows (the L2,1 pattern)
    return jnp.asarray(theta)


def run(smoke: bool | None = None, collect: dict | None = None):
    from repro.data.sparse import generate_sparse
    from repro.eval import auc, calibration_ratio
    from repro.serve import (
        MicroBatchQueue,
        QueueConfig,
        ScoreBundle,
        ScoringEngine,
        as_model,
        compress,
        dequantize,
        envelope_closure,
        load_artifact,
        poisson_arrivals,
        quantize,
        replay_open_loop,
        save_artifact,
        score_bundles,
        score_bundles_naive,
        score_sparse,
        synthetic_requests,
    )

    def _queue_replay(engine, reqs, arrivals, qcfg):
        """Virtual-clock queue replay returning per-ticket scores + the
        round/latency ledger (the coalescing comparison needs scores BY
        TICKET, which replay_open_loop doesn't expose)."""
        q = MicroBatchQueue(engine, qcfg)
        for t, r in zip(arrivals, reqs):
            q.flush_due(t)
            q.submit(r, t)
        q.flush_due(arrivals[-1] + 1.0)
        q.drain(arrivals[-1] + 1.0)
        comps = q.completions
        makespan = max(c.completed for c in comps) - arrivals[0]
        cand = sum(c.scores.shape[0] for c in comps)
        lat = np.array([c.latency_us for c in comps])
        return ({c.ticket: c.scores for c in comps},
                {"rounds": sum(q.stats.flushes.values()),
                 "flushes": dict(q.stats.flushes),
                 "coalesced_groups": q.stats.coalesced_groups,
                 "candidates_per_sec": float(cand / makespan),
                 "p50_us": float(np.percentile(lat, 50)),
                 "p99_us": float(np.percentile(lat, 99))})

    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    rows = []
    results: dict = {}
    if collect is not None:  # bind BEFORE the sweep: a failing run still
        collect["backend"] = jax.default_backend()  # leaves partial data
        collect["smoke"] = smoke
        collect["target_speedup"] = SERVE_TARGET_SPEEDUP
        collect["configs"] = results

    speedups = []
    for (d, m, nnz, G, A, ku, ka, n_flat) in configs:
        tag = f"d{d}_m{m}_G{G}x{A}"
        theta = _model(d, m, nnz)
        full = as_model(theta)
        art = compress(theta)

        # ---- flat path: pruned vs full, bit-identical scores
        rng = np.random.default_rng(1)
        ids = jnp.asarray(rng.integers(0, d, (n_flat, ku)), jnp.int32)
        vals = jnp.asarray(
            rng.normal(size=(n_flat, ku)).astype(np.float32) / np.sqrt(ku))
        flat_full = jax.jit(lambda i, v: score_sparse(full, i, v))
        flat_pruned = jax.jit(lambda i, v: score_sparse(art, i, v))
        np.testing.assert_array_equal(np.asarray(flat_full(ids, vals)),
                                      np.asarray(flat_pruned(ids, vals)))
        t_ff = time_fn(flat_full, ids, vals)
        t_fp = time_fn(flat_pruned, ids, vals)
        size_ratio = (art.theta.size + art.remap.size + art.alive_ids.size) \
            / theta.size
        rows.append((f"serve/flat_full/{tag}", t_ff,
                     f"{n_flat / (t_ff / 1e6):.0f}ads_per_sec"))
        rows.append((f"serve/flat_pruned/{tag}", t_fp,
                     f"{n_flat / (t_fp / 1e6):.0f}ads_per_sec;"
                     f"alive={art.compression:.3f};"
                     f"deployed_size_ratio={size_ratio:.3f};parity=bitwise"))

        # ---- bundles: session-shared vs naive per-ad (pruned model, the
        # production deployment) + AUC/calibration quality gates
        batch = generate_sparse(
            num_features=d, num_user_features_range=(max(1, int(0.6 * d)), d),
            sessions=G, ads_per_session=A, active_user=ku, active_ad=ka,
            seed=2, with_plans=False)
        bundle = ScoreBundle(batch.user_ids, batch.user_vals,
                             batch.ad_ids, batch.ad_vals, batch.session_id)
        shared = jax.jit(lambda b: score_bundles(art, b))
        naive = jax.jit(lambda b: score_bundles_naive(art, b))
        p_shared = np.asarray(shared(bundle))
        p_naive = np.asarray(naive(bundle))
        np.testing.assert_allclose(p_shared, p_naive, rtol=1e-5, atol=1e-6)
        # pruned-vs-full parity holds BITWISE under the same compilation
        # regime (both jitted here; eager-vs-jit is the usual 1-ulp apart)
        p_full_shared = np.asarray(jax.jit(
            lambda b: score_bundles(full, b))(bundle))
        np.testing.assert_array_equal(p_shared, p_full_shared)
        y = np.asarray(batch.y)
        quality = {
            "auc_pruned": auc(y, p_shared),
            "auc_full": auc(y, p_full_shared),
            "calibration_pruned": calibration_ratio(y, p_shared),
            "calibration_full": calibration_ratio(y, p_full_shared),
        }
        assert quality["auc_pruned"] == quality["auc_full"]
        t_sh = time_fn(shared, bundle)
        t_nv = time_fn(naive, bundle)
        speedup = t_nv / t_sh
        speedups.append(speedup)
        B = bundle.ad_ids.shape[0]
        rows.append((f"serve/bundles_naive/{tag}", t_nv,
                     f"{B / (t_nv / 1e6):.0f}ads_per_sec"))
        rows.append((f"serve/bundles_shared/{tag}", t_sh,
                     f"{B / (t_sh / 1e6):.0f}ads_per_sec;"
                     f"{speedup:.2f}x_vs_naive"))

        # ---- int8 artifact: save/load round trip, bounded |dp| vs fp32
        q = quantize(art)
        with tempfile.TemporaryDirectory() as tmp:
            q = load_artifact(save_artifact(f"{tmp}/art_int8", q))
        p_q = np.asarray(jax.jit(lambda i, v: score_sparse(q, i, v))(ids, vals))
        max_dp = float(np.abs(p_q - np.asarray(flat_pruned(ids, vals))).max())
        assert max_dp <= INT8_MAX_DP, \
            f"int8 round trip moved p by {max_dp:.2e} (> {INT8_MAX_DP})"
        fp32_rows_bytes = art.theta.size * 4
        int8_rows_bytes = q.codes.size + q.scales.size * 4
        rows.append((f"serve/int8/{tag}", 0.0,
                     f"max_dp={max_dp:.1e};"
                     f"rows_ratio={int8_rows_bytes / fp32_rows_bytes:.3f};"
                     f"deployed_bytes={q.deployed_bytes}"))

        # ---- engine on ragged traffic: one request per dispatch vs
        # stacked same-envelope G>1 dispatches; both steady states must
        # not recompile, batched must win >= BATCH_TARGET_SPEEDUP at
        # smoke shapes (dispatch overhead dominates there)
        requests = synthetic_requests(
            16 if smoke else 128, num_features=d,
            k_user=(max(2, ku // 2), ku), k_ad=(max(2, ka // 2), ka),
            n_ads=(max(2, A // 2), A), seed=3)
        envs = {ScoringEngine(art).envelope(r) for r in requests}
        reps = 3  # replay a few times so the us-scale dispatches average out

        eng_s = ScoringEngine(art)
        eng_s.warm(envs)  # deploy-time: the G=1 bucket set
        warm_compiles = eng_s.stats.compiles
        for _ in range(reps):
            single = eng_s.score_many(requests)
        s = eng_s.stats
        assert s.compiles == warm_compiles, \
            f"engine recompiled in steady state ({s.compiles} != {warm_compiles})"
        rows.append((f"serve/engine_single/{tag}", s.latency_us,
                     f"{s.candidates_per_sec:.0f}ads_per_sec;"
                     f"buckets={len(s.bucket_hits)};compiles={s.compiles};"
                     "steady_state_recompiles=0"))

        eng_b = ScoringEngine(art)
        eng_b.warm(envs, batch_sizes=eng_b.g_buckets)
        warm_b = eng_b.stats.compiles
        for _ in range(reps):
            batched = eng_b.score_batch(requests)
        b = eng_b.stats
        assert b.compiles == warm_b, \
            f"batched engine recompiled ({b.compiles} != {warm_b})"
        for p_one, p_many in zip(single, batched):  # bitwise parity
            np.testing.assert_array_equal(p_one, p_many)
        batch_speedup = b.candidates_per_sec / s.candidates_per_sec
        rows.append((f"serve/engine_batched/{tag}", b.latency_us,
                     f"{b.candidates_per_sec:.0f}ads_per_sec;"
                     f"{batch_speedup:.2f}x_vs_single;"
                     f"occupancy={b.occupancy:.2f};"
                     f"dispatches={b.dispatches};compiles={b.compiles};"
                     "steady_state_recompiles=0"))
        if smoke:
            assert batch_speedup >= BATCH_TARGET_SPEEDUP, \
                (f"batched dispatch only {batch_speedup:.2f}x vs per-request "
                 f"at smoke shapes (target {BATCH_TARGET_SPEEDUP}x)")

        # ---- int8-native vs dequantized engine: same QuantizedArtifact
        # served as int8 codes + fused scales vs rebuilt fp32 rows.
        # Parity <= 1e-6 asserted BEFORE timing; the native win is the
        # row-gather DMA bytes, so the speedup gate applies on kernel
        # backends only (cpu's jnp fallback does the same flops)
        eng_q = ScoringEngine(q)
        eng_dq = ScoringEngine(dequantize(q))
        eng_q.warm(envs, batch_sizes=eng_q.g_buckets)
        eng_dq.warm(envs, batch_sizes=eng_dq.g_buckets)
        warm_q, warm_dq = eng_q.stats.compiles, eng_dq.stats.compiles
        for _ in range(reps):
            p_native = eng_q.score_batch(requests)
            p_deq = eng_dq.score_batch(requests)
        assert eng_q.stats.compiles == warm_q, "int8-native engine recompiled"
        assert eng_dq.stats.compiles == warm_dq, "dequantized engine recompiled"
        native_dp = max(float(np.abs(a - b).max())
                        for a, b in zip(p_native, p_deq))
        assert native_dp <= INT8_NATIVE_TOL, \
            (f"int8-native moved p by {native_dp:.2e} vs dequantized "
             f"(> {INT8_NATIVE_TOL})")
        sq, sdq = eng_q.stats, eng_dq.stats
        int8_speedup = sq.candidates_per_sec / sdq.candidates_per_sec
        # per gathered row: 2m int8 code bytes + one fp32 scale vs 2m fp32
        rows_dma_ratio = (2 * m + 4) / (8 * m)
        rows.append((f"serve/int8_dequantized/{tag}", sdq.latency_us,
                     f"{sdq.candidates_per_sec:.0f}ads_per_sec;"
                     f"compiles={sdq.compiles};steady_state_recompiles=0"))
        rows.append((f"serve/int8_native/{tag}", sq.latency_us,
                     f"{sq.candidates_per_sec:.0f}ads_per_sec;"
                     f"{int8_speedup:.2f}x_vs_dequantized;"
                     f"max_dp={native_dp:.1e};"
                     f"rows_dma_ratio={rows_dma_ratio:.3f};"
                     f"compiles={sq.compiles};steady_state_recompiles=0"))
        if enforce and not smoke and jax.default_backend() != "cpu" \
                and int8_speedup < INT8_TARGET_SPEEDUP:
            raise AssertionError(
                f"int8-native serving only {int8_speedup:.2f}x vs the "
                f"dequantized engine (target {INT8_TARGET_SPEEDUP}x on "
                f"kernel backends)")

        # ---- open-loop Poisson load through the micro-batching queue:
        # tail latency + throughput at each offered QPS (traffic-shaped
        # serving, steady-state no-recompile asserted)
        qcfg = QueueConfig(max_batch=8,
                           max_delay_us=3_000.0 if smoke else 10_000.0)
        load_reqs = synthetic_requests(
            64 if smoke else 256, num_features=d,
            k_user=(max(2, ku // 2), ku), k_ad=(max(2, ka // 2), ka),
            n_ads=(max(2, A // 2), A), seed=4)
        eng_l = ScoringEngine(art)
        # warm the elementwise-max CLOSURE of the traffic's envelopes:
        # coalesced flushes dispatch at merged envelopes, which must not
        # recompile either
        eng_l.warm(envelope_closure({eng_l.envelope(r) for r in load_reqs}),
                   batch_sizes=eng_l.g_buckets)
        warm_l = eng_l.stats.compiles
        load = {}
        for qps in (500.0, 2_000.0) if smoke else (200.0, 800.0):
            rep = replay_open_loop(eng_l, load_reqs, qps=qps, config=qcfg,
                                   seed=5)
            load[f"{qps:.0f}"] = rep
            rows.append((f"serve/load_qps{qps:.0f}/{tag}",
                         rep["latency_p50_us"],
                         f"p50={rep['latency_p50_us']:.0f}us;"
                         f"p99={rep['latency_p99_us']:.0f}us;"
                         f"{rep['candidates_per_sec']:.0f}ads_per_sec;"
                         f"achieved_qps={rep['achieved_qps']:.0f};"
                         f"occupancy={rep['occupancy']:.2f};"
                         f"rejected={rep['rejected']}"))
        assert eng_l.stats.compiles == warm_l, \
            "queue replay recompiled in steady state"

        # ---- cross-envelope coalescing: the SAME arrival tape with
        # coalesce off vs on, per-ticket scores asserted BITWISE before
        # the round counts mean anything. Run at the lower (deadline-
        # dominated) rate on a FINER-bucketed engine — coarse buckets
        # fold ragged traffic into one envelope, which leaves nothing to
        # coalesce (the exact regime the optimisation targets is many
        # small per-envelope groups)
        co_qps = 500.0 if smoke else 200.0
        eng_c = ScoringEngine(art,
                              k_buckets=(2, 4, 8, 16, 32),
                              n_buckets=(2, 4, 8, 16, 32))
        eng_c.warm(envelope_closure({eng_c.envelope(r) for r in load_reqs}),
                   batch_sizes=eng_c.g_buckets)
        warm_c = eng_c.stats.compiles
        arrivals = poisson_arrivals(len(load_reqs), co_qps, seed=6)
        scores_off, rep_off = _queue_replay(
            eng_c, load_reqs, arrivals, qcfg)
        scores_on, rep_on = _queue_replay(
            eng_c, load_reqs, arrivals, qcfg._replace(coalesce=True))
        assert scores_off.keys() == scores_on.keys()
        for t in scores_off:
            np.testing.assert_array_equal(scores_off[t], scores_on[t])
        assert eng_c.stats.compiles == warm_c, \
            "coalesced replay recompiled in steady state"
        assert rep_on["flushes"]["coalesced"] > 0, \
            "coalescing never fired on the deadline-dominated tape"
        round_ratio = rep_on["rounds"] / rep_off["rounds"]
        rows.append((f"serve/coalesce_off/{tag}", rep_off["p50_us"],
                     f"p99={rep_off['p99_us']:.0f}us;"
                     f"rounds={rep_off['rounds']};"
                     f"{rep_off['candidates_per_sec']:.0f}ads_per_sec"))
        rows.append((f"serve/coalesce_on/{tag}", rep_on["p50_us"],
                     f"p99={rep_on['p99_us']:.0f}us;"
                     f"rounds={rep_on['rounds']};"
                     f"round_ratio={round_ratio:.2f};"
                     f"coalesced={rep_on['flushes']['coalesced']}"
                     f"(merging {rep_on['coalesced_groups']} groups);"
                     f"{rep_on['candidates_per_sec']:.0f}ads_per_sec;"
                     "parity=bitwise"))

        results[tag] = {
            "d": d, "m": m, "nnz_frac": nnz, "sessions": G,
            "ads_per_session": A, "k_user": ku, "k_ad": ka,
            "alive_rows": art.num_alive,
            "deployed_size_ratio": float(size_ratio),
            "flat_full_us": t_ff, "flat_pruned_us": t_fp,
            "bundles_naive_us": t_nv, "bundles_shared_us": t_sh,
            "shared_speedup": speedup,
            "engine_single": s.as_dict(),
            "engine_batched": b.as_dict(),
            "batch_speedup": batch_speedup,
            "int8": {"max_dp": max_dp,
                     "rows_ratio": int8_rows_bytes / fp32_rows_bytes,
                     "deployed_bytes": int(q.deployed_bytes)},
            "int8_native": {"max_dp_vs_dequantized": native_dp,
                            "speedup_vs_dequantized": float(int8_speedup),
                            "rows_dma_ratio": float(rows_dma_ratio),
                            "engine": sq.as_dict()},
            "coalesce": {"off": rep_off, "on": rep_on,
                         "round_ratio": float(round_ratio),
                         "parity": "bitwise"},
            "load": load,
            "quality": quality,
            "parity": "bitwise",
        }

    geomean = float(np.exp(np.mean(np.log(speedups))))
    rows.append(("serve/shared_speedup/geomean", 0.0, f"{geomean:.2f}x_vs_naive"))
    if collect is not None:
        collect["shared_speedup_geomean"] = geomean
    emit(rows)  # before the gate: a failed target must not eat the rows
    if enforce and not smoke and geomean < SERVE_TARGET_SPEEDUP:
        raise AssertionError(
            f"session-shared bundle scoring only {geomean:.2f}x vs the naive "
            f"per-ad path (target {SERVE_TARGET_SPEEDUP}x); per-config: "
            f"{[round(s, 2) for s in speedups]}")
    return results
