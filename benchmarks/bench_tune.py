"""Autotune table payoff: tuned configs vs the hand-picked defaults.

Every row times the SAME public op twice — once pinned to the builtin
defaults the repo shipped with (``block_n=256, block_k=8, chunk=8``,
``block_e=1024``) and once with the knobs left at None so they resolve
from the committed autotune table (``repro.tune``) — and reports the
ratio. Rows:

  * ``fused_fwd``    the public forward (``sparse_gather_matmul``):
                     chunk_fwd on the jnp path, (block_n, block_k) on a
                     kernel backend — whatever the backend actually runs.
  * ``bwd_chunked``  the K-chunked backward scans (chunk_bwd).
  * ``bwd_planned``  the plan-driven backward (block_e on kernel
                     backends; the jnp class-gather path has NO tunable,
                     so off-TPU this row is an info ratio ~1.0).
  * ``train_step``   end-to-end ``value_and_grad`` of the sparse NLL
                     (no plan: fwd + chunked bwd), defaults vs tuned.

The GATE (``REPRO_BENCH_ENFORCE=1``, full shapes): geomean over the
rows where the table RESOLVES A NON-DEFAULT CONFIG must be >=
``TARGET_SPEEDUP`` (1.15x), and at least one such row must exist. Rows
where the sweep kept the default are identities by construction (both
sides run the same trace) — including them would dilute the gate with
guaranteed-1.0 ratios; excluding them makes the gate exactly the claim
the table commits to: *everywhere I differ from the hand-picked
defaults, I win, and on aggregate by >= 1.15x*. ``train_step`` rides
along as a trajectory row only (tuned kernels + untunable overhead).

Both sides of every row are parity-checked against each other before
timing (same math, different block order) — a tuned config that changes
results beyond summation noise fails the bench, not just the gate.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.lsplm_sparse_fused.ops import (
    _dtheta_chunked,
    _dvals_chunked,
    lsplm_sparse_logps,
    pad_theta,
    sparse_gather_matmul,
)
from repro.kernels.lsplm_sparse_fused.ref import sparse_matmul_ref
from repro.kernels.lsplm_sparse_scatter.ops import (
    build_transpose_plan,
    scatter_add_planned,
)
from repro.tune import table as tune

SHAPES = [  # (N, K, d, m) — envelopes where sweeps find real headroom
    (4096, 16, 16_384, 12),   # shared with bench_sparse_fused
    (8192, 16, 100_000, 8),   # K=16 training batch (chunk == K wins big)
    (2048, 64, 100_000, 16),  # serving-style wide-K slate
    (8192, 64, 200_000, 8),   # wide-K training batch
]
SMOKE_SHAPES = [(512, 8, 4_096, 4)]
TARGET_SPEEDUP = 1.15  # geomean gate over the non-default-config rows

_D = tune.BUILTIN_DEFAULTS  # the hand-picked configs being challenged


def _make(N, K, d, m, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, d, (N, K)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(N, K)).astype(np.float32))
    theta = jnp.asarray(rng.normal(size=(d, 2 * m)).astype(np.float32) * 0.1)
    dz = jnp.asarray(rng.normal(size=(N, 2 * m)).astype(np.float32))
    y = jnp.asarray((rng.random(N) < 0.5).astype(np.float32))
    return ids, vals, pad_theta(theta), dz, y


def _assert_close(a, b, tag):
    a, b = np.asarray(a), np.asarray(b)
    scale = max(1.0, float(np.abs(a).max()))
    np.testing.assert_allclose(a / scale, b / scale, rtol=2e-4, atol=2e-5,
                               err_msg=f"tuned/default mismatch at {tag}")


def run(smoke: bool | None = None, collect: dict | None = None):
    if smoke is None:
        smoke = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
    enforce = os.environ.get("REPRO_BENCH_ENFORCE", "") == "1"
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows = []
    results: dict = {}
    if collect is not None:  # bind BEFORE timing: failures keep partial data
        collect["backend"] = jax.default_backend()
        collect["smoke"] = smoke
        collect["target_speedup"] = TARGET_SPEEDUP
        collect["shapes"] = results
    gated: list[float] = []
    kernelish = jax.default_backend() == "tpu"

    for (N, K, d, m) in shapes:
        tag = f"N{N}_K{K}_d{d}_m{m}"
        m2 = 2 * m
        env = tune.fused_envelope(N, K, m2)
        ids, vals, tp, dz, y = _make(N, K, d, m)
        results[tag] = {"N": N, "K": K, "d": d, "m": m, "envelope": env}

        # ---- fused fwd: public op, pinned defaults vs table-resolved
        f_def = jax.jit(lambda i, v, t: sparse_gather_matmul(
            i, v, t, block_n=_D["fused_fwd"]["block_n"],
            block_k=_D["fused_fwd"]["block_k"], chunk=_D["chunk_fwd"]["chunk"]))
        f_tun = jax.jit(lambda i, v, t: sparse_gather_matmul(i, v, t))
        z_ref = sparse_matmul_ref(ids, vals, tp)
        _assert_close(f_def(ids, vals, tp), z_ref, f"fused_fwd/default/{tag}")
        _assert_close(f_tun(ids, vals, tp), z_ref, f"fused_fwd/tuned/{tag}")
        t_def = time_fn(f_def, ids, vals, tp)
        t_tun = time_fn(f_tun, ids, vals, tp)
        sp = t_def / t_tun
        rows.append((f"tune/fused_fwd/{tag}", t_tun, f"{sp:.2f}x_vs_default"))
        results[tag].update(fwd_default_us=t_def, fwd_tuned_us=t_tun,
                            fwd_speedup=sp)
        # gated only when the table diverges from the defaults for the
        # knob this backend's forward actually uses
        fwd_differs = (tune.resolve("fused_fwd", env) != _D["fused_fwd"]
                       if kernelish
                       else tune.resolve("chunk_fwd", env) != _D["chunk_fwd"])
        if fwd_differs:
            gated.append(sp)

        # ---- chunked backward scans: chunk_bwd default vs tuned
        c_tun = tune.resolve("chunk_bwd", env)["chunk"]
        results[tag]["chunk_fwd"] = tune.resolve("chunk_fwd", env)["chunk"]
        results[tag]["chunk_bwd"] = c_tun

        def bwd(chunk):
            return jax.jit(lambda i, v, t, g: (
                _dtheta_chunked(i, v, t, g, chunk),
                _dvals_chunked(i, v, t, g, chunk)))

        b_def, b_tun = bwd(_D["chunk_bwd"]["chunk"]), bwd(c_tun)
        dt_d, dv_d = b_def(ids, vals, tp, dz)
        dt_t, dv_t = b_tun(ids, vals, tp, dz)
        _assert_close(dt_t, dt_d, f"bwd_chunked/dtheta/{tag}")
        _assert_close(dv_t, dv_d, f"bwd_chunked/dvals/{tag}")
        t_def = time_fn(b_def, ids, vals, tp, dz)
        t_tun = time_fn(b_tun, ids, vals, tp, dz)
        sp = t_def / t_tun
        rows.append((f"tune/bwd_chunked/{tag}", t_tun,
                     f"{sp:.2f}x_vs_default"))
        results[tag].update(bwd_default_us=t_def, bwd_tuned_us=t_tun,
                            bwd_speedup=sp)
        if c_tun != _D["chunk_bwd"]["chunk"]:
            gated.append(sp)

        # ---- planned backward: block_e default vs tuned (kernel backends;
        # the jnp class-gather path has no knob — trajectory row only)
        plan = build_transpose_plan(np.asarray(ids), tp.shape[0])
        p_def = jax.jit(lambda v, g: scatter_add_planned(
            plan, v, g, block_e=_D["scatter"]["block_e"]))
        p_tun = jax.jit(lambda v, g: scatter_add_planned(plan, v, g))
        _assert_close(p_tun(vals, dz), p_def(vals, dz), f"bwd_planned/{tag}")
        t_def = time_fn(p_def, vals, dz)
        t_tun = time_fn(p_tun, vals, dz)
        sp = t_def / t_tun
        rows.append((f"tune/bwd_planned/{tag}", t_tun,
                     f"{sp:.2f}x_vs_default"))
        results[tag].update(planned_default_us=t_def, planned_tuned_us=t_tun)
        if kernelish:  # block_e only steers the Pallas run-length kernel
            senv = tune.scatter_envelope(plan.num_kept, m2)
            results[tag]["planned_speedup"] = sp
            if tune.resolve("scatter", senv) != _D["scatter"]:
                gated.append(sp)

        # ---- end-to-end train step (fwd + chunked bwd through the NLL)
        def loss(t, i, v, yy, **kw):
            lp1, lp0 = lsplm_sparse_logps(i, v, t, **kw)
            return -jnp.sum(yy * lp1 + (1.0 - yy) * lp0)

        s_def = jax.jit(jax.value_and_grad(
            lambda t, i, v, yy: loss(
                t, i, v, yy, block_n=_D["fused_fwd"]["block_n"],
                block_k=_D["fused_fwd"]["block_k"],
                chunk=_D["chunk_fwd"]["chunk"])))
        s_tun = jax.jit(jax.value_and_grad(loss))
        l_d, g_d = s_def(tp, ids, vals, y)
        l_t, g_t = s_tun(tp, ids, vals, y)
        _assert_close(l_t, l_d, f"train_step/loss/{tag}")
        _assert_close(g_t, g_d, f"train_step/grad/{tag}")
        t_def = time_fn(s_def, tp, ids, vals, y)
        t_tun = time_fn(s_tun, tp, ids, vals, y)
        sp = t_def / t_tun
        rows.append((f"tune/train_step/{tag}", t_tun,
                     f"{sp:.2f}x_vs_default"))
        results[tag].update(step_default_us=t_def, step_tuned_us=t_tun,
                            step_ratio_vs_default=sp, parity="ok")

    if enforce and not smoke:
        if not gated:
            raise AssertionError(
                "autotune gate: the committed table resolves the builtin "
                "defaults at every bench envelope — it claims no wins on "
                f"backend {jax.default_backend()!r}; re-sweep "
                "(python -m repro.tune.sweep) or fix the bench shapes")
        geomean = float(np.exp(np.mean(np.log(gated))))
        print(f"tune/gate/geomean,0.0,{geomean:.2f}x_vs_default")
        if collect is not None:
            collect["tuned_speedup_geomean"] = geomean
        if geomean < TARGET_SPEEDUP:
            raise AssertionError(
                f"tuned configs only {geomean:.2f}x geomean vs the builtin "
                f"defaults (target {TARGET_SPEEDUP}x) over {len(gated)} "
                f"non-default rows: {[round(g, 2) for g in gated]}")

    emit(rows)
    return results
